//! Failure injection: workers that panic or hang mid-run, with and without
//! the skeleton's degraded-mode recovery — plus redistribution, respawn,
//! and the fault telemetry on the report.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bsf::coordinator::{
    run_sequential, BsfProblem, CostSpec, LiveRunner, PhaseTimeouts, Workspace,
};
use bsf::runtime::KernelRuntime;
use bsf::simulator::RecoveryPolicy;

/// Sums `weight * x` over its list; chosen list indices fail (panic or
/// hang) when mapped on a worker thread inside a given iteration window —
/// simulating node crashes. Multiple bad indices across distinct workers'
/// ranges give true multi-failure scenarios.
#[derive(Debug)]
struct Sabotaged {
    l: usize,
    /// Indices whose Map fails (each kills whatever worker owns it).
    bad: Vec<usize>,
    /// First iteration (0-based) at which the failure fires.
    fail_from: usize,
    /// First iteration at which the failure stops firing (exclusive
    /// window end; `usize::MAX` = forever).
    fail_until: usize,
    /// `Some(d)`: the failure is a hang of duration `d` instead of a
    /// panic. Kept just past the test's gather timeout — burning multiple
    /// seconds against a 400 ms deadline only slows the suite down.
    hang: Option<Duration>,
    /// Artificial per-Map latency (paces iterations so timed machinery
    /// like respawn backoff can be tested without wall-clock slack).
    map_delay: Duration,
    iteration_counter: AtomicUsize,
}

impl Sabotaged {
    fn new(l: usize, bad: &[usize], fail_from: usize) -> Sabotaged {
        Sabotaged {
            l,
            bad: bad.to_vec(),
            fail_from,
            fail_until: usize::MAX,
            hang: None,
            map_delay: Duration::ZERO,
            iteration_counter: AtomicUsize::new(0),
        }
    }

    fn healthy(l: usize) -> Sabotaged {
        Sabotaged::new(l, &[], 0)
    }

    fn with_window(mut self, until: usize) -> Sabotaged {
        self.fail_until = until;
        self
    }

    fn with_hang(mut self, d: Duration) -> Sabotaged {
        self.hang = Some(d);
        self
    }

    fn with_map_delay(mut self, d: Duration) -> Sabotaged {
        self.map_delay = d;
        self
    }
}

impl BsfProblem for Sabotaged {
    fn name(&self) -> &str {
        "sabotaged"
    }
    fn list_len(&self) -> usize {
        self.l
    }
    fn initial_approx(&self) -> Vec<f64> {
        vec![0.0]
    }
    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        _ws: &mut Workspace,
        _k: Option<&KernelRuntime>,
    ) {
        std::thread::sleep(self.map_delay);
        let iter = x[0] as usize; // iteration is encoded in the approximation
        // The injected fault models a *node* failure: it fires only on
        // worker threads (spawned unnamed), never on the master/test
        // thread that recovers the range.
        let on_worker = std::thread::current().name().is_none();
        let in_window = iter >= self.fail_from && iter < self.fail_until;
        if on_worker && in_window && self.bad.iter().any(|b| range.contains(b)) {
            match self.hang {
                Some(d) => std::thread::sleep(d),
                None => panic!("injected worker failure at iteration {iter}"),
            }
        }
        out[0] = range.map(|j| (j + 1) as f64).sum::<f64>() * (x[0] + 1.0);
    }
    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0]
    }
    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        acc[0] += b[0];
    }
    fn post(&self, x: &[f64], s: &[f64], iteration: usize) -> (Vec<f64>, bool) {
        self.iteration_counter.fetch_max(iteration + 1, Ordering::Relaxed);
        // carry the iteration number in the approximation; verify the
        // folded sum is exactly sum(1..=l) * (iter+1). Every value in the
        // fold is a small integer, so any fold order is exact and a
        // dropped/duplicated sublist is detected immediately.
        let expect = (self.l * (self.l + 1) / 2) as f64 * (x[0] + 1.0);
        assert_eq!(s[0], expect, "fold corrupted at iteration {iteration}");
        (vec![(iteration + 1) as f64], iteration + 1 >= 6)
    }
    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.l,
            words_down: 1,
            words_up: 1,
            ops_map_per_elem: 1.0,
            ops_combine: 1.0,
            ops_post: 1.0,
        }
    }
}

fn runner(k: usize, fault_tolerant: bool) -> LiveRunner {
    let mut r = LiveRunner::new(k, 10);
    r.timeouts = Some(PhaseTimeouts {
        scatter: Duration::from_secs(2),
        gather: Duration::from_millis(400),
    });
    r.fault_tolerant = fault_tolerant;
    r
}

#[test]
fn healthy_run_completes() {
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::healthy(64));
    let report = runner(4, false).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
    assert_eq!(report.faults.injected, 0);
    assert_eq!(report.faults.late_uplinks_dropped, 0);
    // surfaced unconditionally at the top level too: clean runs report a
    // hard zero, not an absent field
    assert_eq!(report.late_uplinks_dropped, 0);
    assert_eq!(report.late_uplinks_dropped, report.faults.late_uplinks_dropped);
}

#[test]
fn worker_panic_aborts_without_fault_tolerance() {
    // bad index 40 lands in worker 3's range (64/4 = 16 per worker).
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, &[40], 2));
    let err = runner(4, false).run(p).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("timed out") || msg.contains("panicked") || msg.contains("disconnected"),
        "unexpected error: {msg}"
    );
}

#[test]
fn worker_panic_recovers_with_fault_tolerance() {
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, &[40], 2));
    let report = runner(4, true).run(p).unwrap();
    // The run completes all 6 iterations with correct folds (post() asserts
    // exactness every iteration — the master recomputed the dead range).
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
    assert_eq!(report.faults.injected, 1);
    assert_eq!(report.faults.recovered, 0);
}

#[test]
fn hung_worker_recovers_with_fault_tolerance() {
    // The hang (800 ms) only just outlasts the 400 ms gather deadline —
    // enough to be detected as dead, without burning seconds of suite time.
    let p: Arc<dyn BsfProblem> =
        Arc::new(Sabotaged::new(64, &[10], 3).with_hang(Duration::from_millis(800)));
    let report = runner(4, true).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
    assert_eq!(report.faults.injected, 1);
    // the hung worker's uplink lands after the gather deadline; the
    // top-level mirror must agree with the fault counters
    assert_eq!(report.late_uplinks_dropped, report.faults.late_uplinks_dropped);
}

#[test]
fn multiple_failures_still_recover() {
    // Two bad indices in two distinct workers' ranges (k=4, l=64: index 0
    // is worker 1's, index 40 is worker 3's) — both die, the master
    // carries both sublists, and the telemetry shows two injections.
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, &[0, 40], 0));
    let report = runner(4, true).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
    assert_eq!(report.faults.injected, 2);
}

#[test]
fn recovery_matches_sequential_result() {
    let seq = run_sequential(&Sabotaged::healthy(64), 10, None);
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, &[40], 1));
    let live = runner(4, true).run(p).unwrap();
    assert_eq!(live.final_approx, seq.final_approx);
    assert_eq!(live.iterations, seq.iterations);
}

#[test]
fn redistribution_carries_dead_range_on_survivors() {
    // Worker 3 dies only inside iteration 2, so from iteration 3 its range
    // is safe to hand to a surviving carrier. Redistribution kicks in on
    // every iteration after the death is detected.
    let seq = run_sequential(&Sabotaged::healthy(64), 10, None);
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, &[40], 2).with_window(3));
    let mut r = runner(4, true);
    r.recovery = RecoveryPolicy::Redistribute;
    let live = r.run(p).unwrap();
    assert!(live.converged);
    assert_eq!(live.final_approx, seq.final_approx);
    assert_eq!(live.faults.injected, 1);
    assert!(
        live.faults.redispatched >= 2,
        "dead range should ride survivors each remaining iteration: {:?}",
        live.faults
    );
    assert_eq!(live.faults.recovered, 0);
}

#[test]
fn bounded_respawn_recovers_the_worker() {
    // Death fires only inside iteration 2; the respawned incarnation
    // (backoff 1 ms, iterations paced at ≥2 ms by the map delay) rejoins
    // after the window closed and finishes the run itself.
    let seq = run_sequential(&Sabotaged::healthy(64), 10, None);
    let p: Arc<dyn BsfProblem> = Arc::new(
        Sabotaged::new(64, &[40], 2)
            .with_window(3)
            .with_map_delay(Duration::from_millis(2)),
    );
    let mut r = runner(4, true);
    r.respawn_limit = 2;
    r.respawn_backoff = Duration::from_millis(1);
    let live = r.run(p).unwrap();
    assert!(live.converged);
    assert_eq!(live.final_approx, seq.final_approx);
    assert_eq!(live.faults.injected, 1);
    assert!(
        live.faults.recovered >= 1,
        "worker should have respawned: {:?}",
        live.faults
    );
}

#[test]
fn default_timeouts_derive_from_cost_spec() {
    // No explicit timeouts: the runner derives them from the problem's
    // CostSpec (this tiny problem clamps to the floors) and surfaces the
    // chosen values on the report.
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::healthy(64));
    let report = LiveRunner::new(4, 10).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.gather_timeout, Duration::from_secs(10));
    assert_eq!(report.scatter_timeout, Duration::from_secs(2));
}
