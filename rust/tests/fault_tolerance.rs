//! Failure injection: workers that panic or hang mid-run, with and without
//! the skeleton's degraded-mode recovery.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use bsf::coordinator::{run_sequential, BsfProblem, CostSpec, LiveRunner, Workspace};
use bsf::runtime::KernelRuntime;

/// Sums `weight * x` over its list; a chosen list index panics (or hangs)
/// when mapped after a given iteration — simulating a worker crash.
#[derive(Debug)]
struct Sabotaged {
    l: usize,
    /// Index whose Map fails.
    bad_index: usize,
    /// First iteration (0-based) at which the failure fires.
    fail_from: usize,
    /// If true the failure is a hang (sleep) instead of a panic.
    hang: bool,
    iteration_counter: AtomicUsize,
}

impl Sabotaged {
    fn new(l: usize, bad_index: usize, fail_from: usize, hang: bool) -> Sabotaged {
        Sabotaged { l, bad_index, fail_from, hang, iteration_counter: AtomicUsize::new(0) }
    }
}

impl BsfProblem for Sabotaged {
    fn name(&self) -> &str {
        "sabotaged"
    }
    fn list_len(&self) -> usize {
        self.l
    }
    fn initial_approx(&self) -> Vec<f64> {
        vec![0.0]
    }
    fn map_fold_into(
        &self,
        range: Range<usize>,
        x: &[f64],
        out: &mut [f64],
        _ws: &mut Workspace,
        _k: Option<&KernelRuntime>,
    ) {
        let iter = x[0] as usize; // iteration is encoded in the approximation
        // The injected fault models a *node* failure: it fires only on
        // worker threads (spawned unnamed), never on the master/test
        // thread that recovers the range.
        let on_worker = std::thread::current().name().is_none();
        if on_worker && range.contains(&self.bad_index) && iter >= self.fail_from {
            if self.hang {
                std::thread::sleep(Duration::from_secs(5));
            } else {
                panic!("injected worker failure at iteration {iter}");
            }
        }
        out[0] = range.map(|j| (j + 1) as f64).sum::<f64>() * (x[0] + 1.0);
    }
    fn fold_identity(&self) -> Vec<f64> {
        vec![0.0]
    }
    fn combine_into(&self, acc: &mut [f64], b: &[f64]) {
        acc[0] += b[0];
    }
    fn post(&self, x: &[f64], s: &[f64], iteration: usize) -> (Vec<f64>, bool) {
        self.iteration_counter.fetch_max(iteration + 1, Ordering::Relaxed);
        // carry the iteration number in the approximation; verify the
        // folded sum is exactly sum(1..=l) * (iter+1).
        let expect = (self.l * (self.l + 1) / 2) as f64 * (x[0] + 1.0);
        assert_eq!(s[0], expect, "fold corrupted at iteration {iteration}");
        (vec![(iteration + 1) as f64], iteration + 1 >= 6)
    }
    fn cost_spec(&self) -> CostSpec {
        CostSpec {
            l: self.l,
            words_down: 1,
            words_up: 1,
            ops_map_per_elem: 1.0,
            ops_combine: 1.0,
            ops_post: 1.0,
        }
    }
}

fn runner(k: usize, fault_tolerant: bool) -> LiveRunner {
    let mut r = LiveRunner::new(k, 10);
    r.gather_timeout = Duration::from_millis(400);
    r.fault_tolerant = fault_tolerant;
    r
}

#[test]
fn healthy_run_completes() {
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, usize::MAX, 0, false));
    let report = runner(4, false).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
}

#[test]
fn worker_panic_aborts_without_fault_tolerance() {
    // bad index 40 lands in worker 3's range (64/4 = 16 per worker).
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, 40, 2, false));
    let err = runner(4, false).run(p).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("timed out") || msg.contains("panicked") || msg.contains("disconnected"),
        "unexpected error: {msg}"
    );
}

#[test]
fn worker_panic_recovers_with_fault_tolerance() {
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, 40, 2, false));
    let report = runner(4, true).run(p).unwrap();
    // The run completes all 6 iterations with correct folds (post() asserts
    // exactness every iteration — the master recomputed the dead range).
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
}

#[test]
fn hung_worker_recovers_with_fault_tolerance() {
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, 10, 3, true));
    let report = runner(4, true).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
}

#[test]
fn multiple_failures_still_recover() {
    // Two bad indices in different workers' ranges would need two problems;
    // instead kill worker 1 (index 0) immediately — the master carries 1/4
    // of the list from iteration 0.
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, 0, 0, false));
    let report = runner(4, true).run(p).unwrap();
    assert!(report.converged);
    assert_eq!(report.iterations, 6);
}

#[test]
fn recovery_matches_sequential_result() {
    let seq = run_sequential(&Sabotaged::new(64, usize::MAX, 0, false), 10, None);
    let p: Arc<dyn BsfProblem> = Arc::new(Sabotaged::new(64, 40, 1, false));
    let live = runner(4, true).run(p).unwrap();
    assert_eq!(live.final_approx, seq.final_approx);
    assert_eq!(live.iterations, seq.iterations);
}
