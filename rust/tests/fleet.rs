//! Fleet chaos harness — the fault-tolerance contract, pinned:
//!
//! with any single worker killed (socket dropped mid-lease) or hung
//! (silent past its deadline) at deterministic injection points, the
//! fleet's final result table is **bitwise identical** to the serial
//! single-process sweep, re-leases are observed in the report, and no
//! duplicate completion ever disagrees on bits.
//!
//! Everything runs in-process over localhost TCP: `serve` in one thread,
//! `run_worker` in others, kills simulated by dropping the socket exactly
//! where a real SIGKILL would (the CI fleet-smoke job does it with a real
//! `kill -9`). Timing assertions are deliberately one-sided — false lease
//! expiries under debug-build CI load are bitwise-harmless by design, so
//! no test asserts an *absence* of recovery except under a generous
//! deadline.

use std::net::TcpListener;
use std::thread;
use std::time::Duration;

use bsf::experiments::ProblemKind;
use bsf::fleet::{
    run_worker, serial_times, serve, FleetConfig, FleetGrid, FleetReport, FleetSpec, WorkerChaos,
    WorkerConfig, WorkerSummary,
};

/// Two identical sizes: every K appears in two cells of equal shape, so
/// the partition has real multi-cell buckets and re-leases cross size
/// boundaries.
fn spec() -> FleetSpec {
    FleetSpec {
        problem: ProblemKind::Jacobi,
        sizes: vec![1_500, 1_500],
        iters: 2,
        seed: 0xF1EE7,
        quick: true,
        jitter: 0.05,
    }
}

/// Generous deadlines: nothing should expire unless a worker is truly
/// gone for many seconds.
fn loose_cfg() -> FleetConfig {
    FleetConfig {
        heartbeat: Duration::from_millis(50),
        grace: 100,
        min_deadline: Duration::from_secs(20),
        safety: 50.0,
        lease_target: Duration::from_millis(200),
        max_lease_cells: 16,
        idle_timeout: Duration::from_secs(60),
    }
}

/// Tight deadlines: a silent worker expires in ~a quarter second.
fn tight_cfg() -> FleetConfig {
    FleetConfig {
        heartbeat: Duration::from_millis(25),
        grace: 4,
        min_deadline: Duration::from_millis(200),
        safety: 1.0,
        lease_target: Duration::from_millis(500),
        max_lease_cells: 16,
        idle_timeout: Duration::from_secs(60),
    }
}

/// Run one fleet: a coordinator plus one worker per chaos entry.
fn run_fleet(
    spec: FleetSpec,
    cfg: FleetConfig,
    chaos: &[WorkerChaos],
) -> (Vec<f64>, FleetReport, Vec<anyhow::Result<WorkerSummary>>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("addr").to_string();
    let grid = FleetGrid::new(spec).expect("grid");
    let coord = thread::spawn(move || serve(&grid, &cfg, listener).expect("serve"));
    let workers: Vec<_> = chaos
        .iter()
        .enumerate()
        .map(|(i, &ch)| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut wc = WorkerConfig::new(addr, format!("chaos-w{i}"));
                wc.connect_base = Duration::from_millis(1);
                wc.connect_attempts = 8;
                wc.chaos = ch;
                run_worker(&wc)
            })
        })
        .collect();
    let (times, report) = coord.join().expect("coordinator thread");
    let summaries = workers.into_iter().map(|h| h.join().expect("worker thread")).collect();
    (times, report, summaries)
}

fn assert_bitwise(times: &[f64], truth: &[f64]) {
    assert_eq!(times.len(), truth.len());
    for (r, (a, b)) in times.iter().zip(truth).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "cell {r}: fleet {a:e} != serial {b:e}");
    }
}

#[test]
fn repeated_size_grid_has_multicell_buckets() {
    let grid = FleetGrid::new(spec()).unwrap();
    let jobs = grid.jobs();
    let flat = bsf::experiments::flat_cells(&jobs);
    let groups = bsf::experiments::cell_groups(&jobs, &flat);
    assert!(
        groups.iter().any(|g| g.len() >= 2),
        "chaos grid must exercise multi-cell buckets, got all singletons"
    );
    assert_eq!(groups.iter().map(Vec::len).sum::<usize>(), flat.len());
}

#[test]
fn clean_fleet_matches_serial_bitwise() {
    let truth = serial_times(&FleetGrid::new(spec()).unwrap());
    let chaos = [WorkerChaos::default(); 3];
    let (times, report, summaries) = run_fleet(spec(), loose_cfg(), &chaos);
    assert_bitwise(&times, &truth);
    // >= 1, not == 3: a worker may in principle join after the grid
    // drains under extreme scheduler starvation
    assert!(report.workers_joined >= 1, "{report:?}");
    assert_eq!(report.cells, truth.len());
    assert_eq!(report.releases, 0, "clean run must not re-lease: {report:?}");
    assert_eq!(report.leases_expired, 0);
    assert_eq!(report.duplicate_mismatches, 0);
    let executed: usize = summaries.iter().map(|s| s.as_ref().unwrap().cells).sum();
    assert_eq!(executed, truth.len(), "each cell executed exactly once");
}

/// The acceptance chaos contract: a worker SIGKILLed mid-lease at each of
/// three deterministic injection points; the fleet must recover with a
/// bitwise-identical table and at least one re-lease.
#[test]
fn killed_worker_recovers_bitwise_at_three_injection_points() {
    let truth = serial_times(&FleetGrid::new(spec()).unwrap());
    for kill_at in [1usize, 4, 9] {
        let chaos = [
            WorkerChaos::default(),
            WorkerChaos::default(),
            WorkerChaos { kill_after_cells: Some(kill_at), ..Default::default() },
        ];
        let (times, report, summaries) = run_fleet(spec(), loose_cfg(), &chaos);
        assert_bitwise(&times, &truth);
        assert!(report.releases >= 1, "kill@{kill_at}: no re-lease observed: {report:?}");
        assert!(report.worker_deaths >= 1, "kill@{kill_at}: {report:?}");
        assert_eq!(report.duplicate_mismatches, 0, "kill@{kill_at}: {report:?}");
        let killed = summaries[2].as_ref().unwrap();
        assert!(killed.killed, "kill@{kill_at}: chaos kill never fired");
    }
}

/// Lease-expiry edge case: the original owner goes silent past its
/// deadline, the batch is re-leased, and the owner's late completion is
/// accepted (duplicate, never a mismatch).
#[test]
fn hung_worker_expires_then_late_completion_is_safe() {
    let truth = serial_times(&FleetGrid::new(spec()).unwrap());
    let chaos = [
        WorkerChaos::default(),
        WorkerChaos {
            hang_after_cells: Some(2),
            hang_hold: Duration::from_secs(2),
            ..Default::default()
        },
    ];
    let (times, report, summaries) = run_fleet(spec(), tight_cfg(), &chaos);
    assert_bitwise(&times, &truth);
    assert!(report.leases_expired >= 1, "hang never expired a lease: {report:?}");
    assert!(report.releases >= 1);
    assert_eq!(report.duplicate_mismatches, 0, "{report:?}");
    // the hung worker was never killed and exited cleanly
    assert!(!summaries[1].as_ref().unwrap().killed);
}

/// Lease-expiry edge case: duplicate completion of the same cells — the
/// owner delays its `Done` past the deadline, a peer re-executes, and
/// both completions are recorded with identical bits.
#[test]
fn delayed_done_yields_duplicate_completion_not_mismatch() {
    let truth = serial_times(&FleetGrid::new(spec()).unwrap());
    let chaos = [
        WorkerChaos { done_delay: Some(Duration::from_millis(800)), ..Default::default() },
        WorkerChaos::default(),
    ];
    let (times, report, _) = run_fleet(spec(), tight_cfg(), &chaos);
    assert_bitwise(&times, &truth);
    assert!(
        report.duplicate_completions >= 1,
        "delayed Done should duplicate at least one cell: {report:?}"
    );
    assert_eq!(report.duplicate_mismatches, 0, "duplicates must agree bitwise: {report:?}");
}

/// Lease-expiry edge case: the coordinator finishes (and vanishes) while
/// a worker still thinks it holds a lease — the worker drains, fails to
/// reconnect, and exits cleanly (the process-level contract behind the
/// CI smoke job's `wait` on worker exit codes).
#[test]
fn coordinator_shutdown_with_outstanding_lease_drains_worker() {
    let truth = serial_times(&FleetGrid::new(spec()).unwrap());
    let chaos = [
        WorkerChaos::default(),
        WorkerChaos {
            hang_after_cells: Some(0), // hang immediately on the first lease
            hang_hold: Duration::from_secs(3),
            ..Default::default()
        },
    ];
    let (times, report, summaries) = run_fleet(spec(), tight_cfg(), &chaos);
    assert_bitwise(&times, &truth);
    assert!(report.leases_expired >= 1, "{report:?}");
    let straggler = summaries[1].as_ref().expect("straggler must exit cleanly (exit 0)");
    assert!(!straggler.killed);
    // whatever it executed after the coordinator left was drained work
    assert_eq!(truth.len(), report.cells);
}
