//! Fault-plane contracts (PERF.md "Fault plane"):
//!
//! 1. **Empty plan == plain engine, bitwise.** Replaying under a fault
//!    plan with no failures, no stragglers, and unit speeds must produce
//!    the clean template's exact timings *and* the same scheduler counter
//!    activity (order-cache hits, fallbacks, lane batching) — the fault
//!    plane must not disturb the `BSF_SCHED`/`BSF_LANES` caches. CI runs
//!    this suite under every kernel/scheduler/lane cell, plus a
//!    `BSF_FAULTS=audit` cell that routes even empty plans through the
//!    faulty provider wrapper.
//! 2. **Pooled faulty sweeps == serial, bitwise.** Fault draws ride per-K
//!    split streams exactly like the clean timing draws, so thread count
//!    must not move a single bit.
//! 3. **Faults only add work.** With pure failure injection (no
//!    speed/straggler variation), the faulty mean iteration time is never
//!    below the clean one.

use bsf::experiments::{simulated_curves, SweepJob};
use bsf::simulator::{
    run_faulty_into, AnalyticCost, FaultPlan, FaultScratch, FaultSpec, IterationTemplate,
    IterationTiming, RecoveryPolicy, SimParams,
};
use bsf::util::Rng;

fn assert_bitwise_eq(a: &IterationTiming, b: &IterationTiming, what: &str) {
    for (x, y, field) in [
        (a.broadcast_done, b.broadcast_done, "broadcast_done"),
        (a.map_done, b.map_done, "map_done"),
        (a.reduce_done, b.reduce_done, "reduce_done"),
        (a.post_done, b.post_done, "post_done"),
        (a.total, b.total, "total"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {field} differs ({x} vs {y})");
    }
}

#[test]
fn empty_plan_races_plain_engine_bitwise() {
    // Deterministic and jittered configurations, several (k, l) cells.
    for (jitter_comp, jitter_comm) in [(0.0, 0.0), (0.12, 0.07)] {
        for (k, l) in [(1usize, 64usize), (8, 1_024), (24, 2_048)] {
            let mut params = SimParams::new(l, l);
            params.jitter_comp = jitter_comp;
            params.jitter_comm = jitter_comm;
            let mut prov_clean = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
            let mut prov_faulty = prov_clean.clone();

            let mut clean = IterationTemplate::new(k, l, &params);
            let mut want = Vec::new();
            clean.run_into(9, &mut prov_clean, &mut Rng::new(0xFA0), &mut want);

            let mut faulty = IterationTemplate::new(k, l, &params);
            let plan = FaultPlan::clean(k);
            assert!(plan.is_empty());
            let mut got = Vec::new();
            let mut scratch = FaultScratch::default();
            run_faulty_into(
                &mut faulty,
                &plan,
                l,
                &params,
                9,
                &mut prov_faulty,
                &mut Rng::new(0xFA0),
                &mut got,
                &mut scratch,
            );

            assert_eq!(want.len(), got.len());
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_bitwise_eq(a, b, &format!("k={k} l={l} jitter={jitter_comp} iter={i}"));
            }
            // The scheduler's cache activity must match too: same order
            // cache hits, same fallbacks, same lane batching. An empty
            // plan that silently forced fallbacks would pass the timing
            // check while destroying the perf contracts.
            assert_eq!(
                clean.sched_counters(),
                faulty.sched_counters(),
                "k={k} l={l} jitter={jitter_comp}: scheduler activity diverged"
            );
            let c = clean.sched_counters();
            assert!(
                c.cached_hits + c.fallbacks + c.calendar_runs >= 1,
                "counters recorded no scheduler activity at all"
            );
        }
    }
}

#[test]
fn pooled_faulty_sweeps_bitwise_equal_serial() {
    let l = 1_500;
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.1;
    let prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let ks: Vec<usize> = (1..=24).collect();
    let spec = FaultSpec {
        speed_sigma: 0.1,
        straggler_prob: 0.2,
        straggler_factor: 3.0,
        fail_prob: 0.05,
        downtime: 2,
        policy: RecoveryPolicy::Redistribute,
        speed_drift: 0.0,
        hazard_drift: 0.0,
    };
    let mk_jobs = |rng: &mut Rng| {
        vec![
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 4, rng).with_fault(spec),
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 4, rng)
                .with_fault(FaultSpec { policy: RecoveryPolicy::MasterRecompute, ..spec }),
        ]
    };
    let reference = simulated_curves(&mk_jobs(&mut Rng::new(0xFA2)), 1);
    for threads in [1usize, 4, 8] {
        let got = simulated_curves(&mk_jobs(&mut Rng::new(0xFA2)), threads);
        assert_eq!(reference.len(), got.len());
        for (sweep, (want, have)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(want.len(), have.len());
            for (a, b) in want.iter().zip(have.iter()) {
                assert_eq!(a.k, b.k, "threads={threads}");
                assert_eq!(
                    a.t_k.to_bits(),
                    b.t_k.to_bits(),
                    "threads={threads} sweep={sweep} K={}: t_k {} vs {}",
                    a.k,
                    a.t_k,
                    b.t_k
                );
                assert_eq!(
                    a.speedup.to_bits(),
                    b.speedup.to_bits(),
                    "threads={threads} sweep={sweep} K={}",
                    a.k
                );
            }
        }
    }
}

#[test]
fn pooled_nonstationary_sweeps_bitwise_equal_serial() {
    // Time-varying plans — drifting speeds, a rising hazard, and
    // checkpoint/restart replay loops — must ride the same per-K split
    // streams as the stationary fault plane: thread count moves no bits.
    let l = 1_200;
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.08;
    let prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let ks: Vec<usize> = (1..=16).collect();
    let drift = FaultSpec { speed_drift: 0.03, ..FaultSpec::clean() };
    let hazard = FaultSpec {
        fail_prob: 0.03,
        hazard_drift: 2.0,
        downtime: 2,
        policy: RecoveryPolicy::Redistribute,
        ..FaultSpec::clean()
    };
    let ckpt = FaultSpec {
        fail_prob: 0.05,
        downtime: 2,
        policy: RecoveryPolicy::Checkpoint { interval: 3 },
        ..FaultSpec::clean()
    };
    let mk_jobs = |rng: &mut Rng| {
        vec![
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 4, rng).with_fault(drift),
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 4, rng).with_fault(hazard),
            SweepJob::new(params.clone(), l, &prov, ks.clone(), 4, rng).with_fault(ckpt),
        ]
    };
    let reference = simulated_curves(&mk_jobs(&mut Rng::new(0xFA6)), 1);
    for threads in [1usize, 4, 8] {
        let got = simulated_curves(&mk_jobs(&mut Rng::new(0xFA6)), threads);
        assert_eq!(reference.len(), got.len());
        for (sweep, (want, have)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(want.len(), have.len());
            for (a, b) in want.iter().zip(have.iter()) {
                assert_eq!(a.k, b.k, "threads={threads}");
                assert_eq!(
                    a.t_k.to_bits(),
                    b.t_k.to_bits(),
                    "threads={threads} sweep={sweep} K={}: t_k {} vs {}",
                    a.k,
                    a.t_k,
                    b.t_k
                );
            }
        }
    }
}

#[test]
fn zero_drift_spec_is_the_stationary_plan() {
    // The new drift knobs at zero must change nothing: generated plans
    // stay static, draw no extra randomness, and static replays still
    // ride the clean graph (same scheduler counter activity).
    let (k, l) = (8usize, 1_024usize);
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.05;
    let root = Rng::new(0xFA4);

    // A fully clean spec generates the exact empty plan: unit speeds to
    // the bit, no windows, classified empty.
    let p0 = FaultPlan::generate(&FaultSpec::clean(), k, 50, &root);
    assert!(p0.is_empty());
    assert!(p0.speeds().iter().all(|s| s.to_bits() == 1.0f64.to_bits()));

    // Heterogeneous but stationary: static classification, and the
    // multiplier is time-invariant to the bit.
    let spec = FaultSpec { speed_sigma: 0.2, ..FaultSpec::clean() };
    let plan = FaultPlan::generate(&spec, k, 50, &root);
    assert!(!plan.is_empty());
    assert!(plan.is_static(), "no failures, no drift, no checkpoint ⇒ static");
    for w in 0..k {
        assert_eq!(
            plan.mult(w, 0).to_bits(),
            plan.mult(w, 49).to_bits(),
            "worker {w}: stationary multiplier drifted"
        );
    }

    // The static fast path replays the clean graph: identical scheduler
    // cache activity to a clean template run of the same shape.
    let mut prov_clean = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let mut prov_faulty = prov_clean.clone();
    let mut clean = IterationTemplate::new(k, l, &params);
    let mut want = Vec::new();
    clean.run_into(7, &mut prov_clean, &mut Rng::new(0xFA5), &mut want);
    let mut faulty = IterationTemplate::new(k, l, &params);
    let mut got = Vec::new();
    let mut scratch = FaultScratch::default();
    run_faulty_into(
        &mut faulty,
        &plan,
        l,
        &params,
        7,
        &mut prov_faulty,
        &mut Rng::new(0xFA5),
        &mut got,
        &mut scratch,
    );
    assert_eq!(want.len(), got.len());
    assert_eq!(
        clean.sched_counters(),
        faulty.sched_counters(),
        "static plan left the clean-graph path"
    );
}

#[test]
fn checkpoint_without_failures_costs_exactly_the_save_task() {
    // A Checkpoint plan with zero failures must replay the clean timeline
    // bitwise, except that every save iteration's total grows by exactly
    // the one Fixed save task (one downlink payload) — a single float
    // add, no rng perturbation anywhere.
    let (k, l) = (6usize, 512usize);
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.08;
    params.jitter_comm = 0.05;
    let iters = 9;
    let interval = 4u64;
    let mut prov_clean = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let mut prov_ckpt = prov_clean.clone();

    let mut clean = IterationTemplate::new(k, l, &params);
    let mut want = Vec::new();
    clean.run_into(iters, &mut prov_clean, &mut Rng::new(0xFA7), &mut want);

    let plan =
        FaultPlan::clean(k).with_policy(RecoveryPolicy::Checkpoint { interval });
    assert!(!plan.is_empty() && !plan.is_static(), "checkpointing is time-varying");
    let mut ckpt = IterationTemplate::new(k, l, &params);
    let mut got = Vec::new();
    let mut scratch = FaultScratch::default();
    run_faulty_into(
        &mut ckpt,
        &plan,
        l,
        &params,
        iters,
        &mut prov_ckpt,
        &mut Rng::new(0xFA7),
        &mut got,
        &mut scratch,
    );

    assert_eq!(want.len(), got.len());
    let save_cost = params.net.p2p(l);
    for (i, (a, b)) in want.iter().zip(&got).enumerate() {
        if i as u64 % interval == 0 {
            // Everything up to post is untouched; the total grows by the
            // save task alone.
            assert_eq!(a.post_done.to_bits(), b.post_done.to_bits(), "save iter {i}");
            assert_eq!(
                b.total.to_bits(),
                (a.total + save_cost).to_bits(),
                "save iter {i}: {} vs {} + {save_cost}",
                b.total,
                a.total
            );
        } else {
            assert_bitwise_eq(a, b, &format!("non-save iter {i}"));
        }
    }
}

#[test]
fn failure_injection_never_speeds_up_the_sweep() {
    // Pure failure injection (unit speeds, no stragglers): recovery only
    // adds Map tasks and comm edges to the timeline, so every K-point's
    // mean iteration time is at least the clean one.
    let l = 1_500;
    let params = SimParams::new(l, l);
    let prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let ks: Vec<usize> = (2..=20).collect();
    let spec = FaultSpec {
        speed_sigma: 0.0,
        straggler_prob: 0.0,
        straggler_factor: 1.0,
        fail_prob: 0.08,
        downtime: 2,
        policy: RecoveryPolicy::MasterRecompute,
        speed_drift: 0.0,
        hazard_drift: 0.0,
    };
    let jobs = vec![
        SweepJob::new(params.clone(), l, &prov, ks.clone(), 5, &mut Rng::new(9)),
        SweepJob::new(params.clone(), l, &prov, ks.clone(), 5, &mut Rng::new(9)).with_fault(spec),
    ];
    let curves = simulated_curves(&jobs, 4);
    let mut any_slower = false;
    for (clean, faulty) in curves[0].iter().zip(&curves[1]) {
        assert_eq!(clean.k, faulty.k);
        assert!(
            faulty.t_k >= clean.t_k,
            "K={}: faulty {} < clean {}",
            clean.k,
            faulty.t_k,
            clean.t_k
        );
        if faulty.t_k > clean.t_k {
            any_slower = true;
        }
    }
    assert!(any_slower, "no failure was drawn anywhere in the sweep — spec too weak");
}
