//! Integration: the `bsf` binary end-to-end (argument parsing, experiment
//! dispatch, CSV output).

use std::process::Command;

fn bsf() -> Command {
    Command::new(env!("CARGO_BIN_EXE_bsf"))
}

#[test]
fn no_args_prints_usage() {
    let out = bsf().output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("usage"), "{err}");
}

#[test]
fn predict_jacobi_published_params() {
    let out = bsf()
        .args(["predict", "--problem=jacobi", "--n=10000"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(stdout.contains("111.7"), "{stdout}"); // K_BSF for n=10000
}

#[test]
fn experiment_table3_quick_writes_csv() {
    let tmp = std::env::temp_dir().join("bsf_cli_test_results");
    let _ = std::fs::remove_dir_all(&tmp);
    let out = bsf()
        .args([
            "experiment",
            "table3",
            "--quick=1",
            &format!("--out={}", tmp.display()),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let csv = std::fs::read_to_string(tmp.join("table3.csv")).unwrap();
    assert!(csv.lines().count() >= 5, "{csv}");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn unknown_experiment_rejected() {
    let out = bsf().args(["experiment", "fig99"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown experiment"));
}

#[test]
fn cluster_overrides_accepted() {
    let tmp = std::env::temp_dir().join("bsf_cli_test_results2");
    let out = bsf()
        .args([
            "experiment",
            "sqrt-law",
            "--cluster.latency=1e-6",
            "--cluster.collective=tree",
            &format!("--out={}", tmp.display()),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn bad_cluster_value_reports_error() {
    let out = bsf()
        .args(["experiment", "table3", "--cluster.collective=ring"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("tree|linear"));
}
