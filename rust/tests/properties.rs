//! Property-based tests (seeded randomized sweeps — the offline build has
//! no proptest, so cases are generated with the crate's own deterministic
//! RNG; failures print the case seed for replay).
//!
//! Invariants covered:
//! * the promotion theorem (eq. 5) for random functions/partitions;
//! * partition coverage/disjointness/balance (eq. 4);
//! * speedup properties (10)–(12) and Proposition-1 unimodality on random
//!   cost parameters;
//! * the closed-form boundary vs numeric argmax;
//! * simulator determinism and phase ordering on random configurations;
//! * the engine's calendar event queue vs a reference binary-heap
//!   scheduler on random DAGs (bitwise finish times + per-resource order,
//!   time ties included);
//! * the order-cached linear replay vs the reference heap on random DAGs
//!   with durations re-perturbed across replays — cache hits and
//!   validity-check fallbacks both exercised, both bitwise-pinned;
//! * the lane-batched replay (`Engine::run_lanes`) at both dispatch
//!   widths (4 and 8) vs the scalar one-at-a-time `run_reuse` loop on
//!   random DAGs — gently perturbed and tie-heavy per-lane redraws force
//!   both vector hits and per-lane fallbacks, both bitwise-pinned — plus
//!   padded remainder batches (1 ≤ lanes < width, pad lanes discarded)
//!   under the same adversarial redraws;
//! * the shape-class grouping key: random `SimParams` pairs that agree on
//!   the structural fields (k, masters, algo, reduce mode) but differ in
//!   payload (list size, word counts, network model, jitter) must produce
//!   equal `ShapeClass` keys AND structurally identical graphs (task
//!   count, resources, edges, tag column, fold counts); perturbing any
//!   structural field must split the key, so grouping can never pair
//!   templates with different graphs (missed-match-only contract);
//! * collective schedules: full coverage and log-depth for random K;
//! * the SIMD-dispatched matvec kernels: AVX2 == scalar **bitwise** on
//!   random shapes (remainder rows/columns included), and the blocked
//!   `col_block_matvec_acc` equals its per-row scalar composition bitwise
//!   whichever kernel the process selected.

use bsf::linalg::{kernels, Matrix};
use bsf::lists::{map_reduce, partition_even, reduce, Add, Monoid, VecAdd};
use bsf::model::{BsfModel, CostParams};
use bsf::net::{CollectiveAlgo, CollectiveSchedule, NetworkParams};
use bsf::simulator::{
    simulate_iteration, AnalyticCost, Engine, IterationTemplate, ReduceMode, ReferenceScheduler,
    SchedMode, ShapeClass, SimParams, TaskId,
};
use bsf::util::Rng;

const CASES: usize = 200;

#[test]
fn prop_promotion_theorem_scalar() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let l = 1 + rng.below(500) as usize;
        let k = 1 + rng.below(40) as usize;
        let xs: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
        let c = rng.range(-2.0, 2.0);
        let f = |x: &f64| c * x + x * x;
        let full = map_reduce(f, &Add, &xs);
        let parts = partition_even(l, k);
        let partials: Vec<f64> = parts.ranges().map(|r| map_reduce(f, &Add, &xs[r])).collect();
        let folded = reduce(&Add, partials);
        assert!(
            (full - folded).abs() <= 1e-9 * full.abs().max(1.0),
            "case {case}: l={l} k={k}"
        );
    }
}

#[test]
fn prop_promotion_theorem_vector() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..50 {
        let l = 1 + rng.below(200) as usize;
        let k = 1 + rng.below(16) as usize;
        let dim = 1 + rng.below(8) as usize;
        let m = VecAdd { n: dim };
        let xs: Vec<f64> = (0..l).map(|_| rng.normal()).collect();
        let f = |x: &f64| -> Vec<f64> { (0..dim).map(|d| x * (d as f64 + 1.0)).collect() };
        let full = map_reduce(f, &m, &xs);
        let parts = partition_even(l, k);
        let partials: Vec<Vec<f64>> = parts.ranges().map(|r| map_reduce(f, &m, &xs[r])).collect();
        let folded = reduce(&m, partials);
        for d in 0..dim {
            assert!((full[d] - folded[d]).abs() < 1e-9, "case {case} dim {d}");
        }
    }
}

#[test]
fn prop_partition_invariants() {
    let mut rng = Rng::new(0xCAFE);
    for case in 0..CASES {
        let l = rng.below(10_000) as usize;
        let k = 1 + rng.below(128) as usize;
        let p = partition_even(l, k);
        assert_eq!(p.k(), k, "case {case}");
        assert_eq!(p.len(), l, "case {case}");
        let mut at = 0;
        let mut min = usize::MAX;
        let mut max = 0usize;
        for r in p.ranges() {
            assert_eq!(r.start, at, "case {case}: gap/overlap");
            at = r.end;
            min = min.min(r.len());
            max = max.max(r.len());
        }
        assert_eq!(at, l, "case {case}: coverage");
        assert!(max - min <= 1, "case {case}: balance");
    }
}

fn random_params(rng: &mut Rng) -> CostParams {
    CostParams {
        l: 100 + rng.below(50_000) as usize,
        t_c: 10f64.powf(rng.range(-5.0, -2.0)),
        t_p: 10f64.powf(rng.range(-7.0, -4.0)),
        t_map: 10f64.powf(rng.range(-3.0, 0.0)),
        t_a: 10f64.powf(rng.range(-9.0, -5.0)),
    }
}

#[test]
fn prop_speedup_properties_10_11() {
    let mut rng = Rng::new(0x5EED);
    for case in 0..CASES {
        let m = BsfModel::new(random_params(&mut rng));
        assert!((m.speedup(1) - 1.0).abs() < 1e-12, "case {case}: property (10)");
        for k in [2usize, 17, 333, 4_096] {
            assert!(m.speedup(k) > 0.0, "case {case}: property (11) at K={k}");
        }
    }
}

#[test]
fn prop_boundary_is_argmax() {
    let mut rng = Rng::new(0xA11);
    for case in 0..60 {
        let m = BsfModel::new(random_params(&mut rng));
        let k0 = m.k_bsf();
        if !(2.0..5_000.0).contains(&k0) {
            continue; // keep the numeric sweep bounded
        }
        let numeric = m.k_bsf_numeric(12_000) as f64;
        assert!(
            (k0 - numeric).abs() <= 1.0 + 0.01 * k0,
            "case {case}: closed {k0:.2} vs numeric {numeric}"
        );
        // Unimodality (Proposition 1): strictly better than far-away Ks.
        let peak = m.speedup(k0.round() as usize);
        assert!(peak >= m.speedup((k0 * 3.0) as usize), "case {case}");
        assert!(peak >= m.speedup(((k0 / 3.0) as usize).max(1)), "case {case}");
    }
}

#[test]
fn prop_simulator_deterministic_and_ordered() {
    let mut rng = Rng::new(0xD15C);
    for case in 0..60 {
        let l = 64 + rng.below(8_000) as usize;
        let k = 1 + rng.below(256) as usize;
        let mut prov = AnalyticCost {
            t_map_full: 10f64.powf(rng.range(-3.0, 0.0)),
            l,
            t_a: 10f64.powf(rng.range(-9.0, -5.0)),
            t_p: 1e-5,
        };
        let params = SimParams::new(l.min(4096), l.min(4096));
        let a = simulate_iteration(k, l, &params, &mut prov, &mut Rng::new(case));
        let b = simulate_iteration(k, l, &params, &mut prov, &mut Rng::new(case + 999));
        assert_eq!(a, b, "case {case}: zero-jitter must be rng-independent");
        assert!(a.broadcast_done > 0.0, "case {case}");
        assert!(a.map_done >= a.broadcast_done, "case {case}");
        assert!(a.reduce_done >= a.map_done, "case {case}");
        assert!(a.post_done >= a.reduce_done, "case {case}");
        assert!(a.total >= a.post_done, "case {case}");
    }
}

#[test]
fn prop_calendar_queue_matches_reference_heap_on_random_dags() {
    let mut rng = Rng::new(0xCA1E);
    for case in 0..120u64 {
        let n = 1 + rng.below(180) as usize;
        let n_res = 1 + rng.below(8) as u32;
        // Duration mix: a coarse discrete grid (including zero) forces
        // frequent exact time ties; a continuous tail keeps buckets busy.
        let mut resources = Vec::with_capacity(n);
        let mut durations = Vec::with_capacity(n);
        let mut eng = Engine::new();
        for _ in 0..n {
            let res = rng.below(n_res as u64) as u32;
            let dur = if rng.below(2) == 0 {
                rng.below(4) as f64 * 0.25
            } else {
                rng.range(0.0, 3.0)
            };
            resources.push(res);
            durations.push(dur);
            eng.task(res, dur);
        }
        // Random forward edges (acyclic by construction): denser near the
        // diagonal so long dependency chains appear regularly.
        let mut edges: Vec<(TaskId, TaskId)> = Vec::new();
        for j in 1..n {
            let tries = 1 + rng.below(3);
            for _ in 0..tries {
                let i = rng.below(j as u64) as usize;
                eng.dep(i as TaskId, j as TaskId);
                edges.push((i as TaskId, j as TaskId));
            }
        }
        let mut reference = ReferenceScheduler::new(resources.clone(), durations.clone(), &edges);
        reference.record_order(true);
        let want_finish = reference.run().to_vec();
        let want_order = reference.resource_order();
        let got_finish = eng.run();
        assert_eq!(want_finish.len(), got_finish.len(), "case {case}");
        for (i, (w, g)) in want_finish.iter().zip(&got_finish).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "case {case}: task {i} finish {w} vs {g} (n={n}, res={n_res})"
            );
        }
        // Per-resource order: walking the reference scheduler's pop order,
        // the engine's task intervals must tile each resource back to back
        // without overlap — same execution order, same idle gaps.
        for (res, tasks) in want_order.iter().enumerate() {
            let mut clock: f64 = 0.0;
            for &id in tasks {
                let i = id as usize;
                // `finish - duration` re-derives the start and can round a
                // ulp below the true value; compare with a relative slack.
                let start = got_finish[i] - durations[i];
                assert!(
                    start >= clock - 1e-9 * (clock + 1.0),
                    "case {case}: resource {res} order/overlap at task {id}"
                );
                clock = got_finish[i];
            }
        }
        // Replays of the same graph stay bitwise stable.
        let replay = eng.run_reuse();
        for (w, g) in want_finish.iter().zip(replay) {
            assert_eq!(w.to_bits(), g.to_bits(), "case {case}: replay drift");
        }
    }
}

#[test]
fn prop_order_cached_replay_matches_reference_on_random_dags() {
    // Race the order-cached linear replay against the reference heap on
    // random DAGs whose durations are re-perturbed between replays:
    // identical and gently nudged durations mostly keep the cached pop
    // order valid (hits), while coarse tie-heavy grid redraws scramble
    // the ready order wholesale and force the validity check to reject
    // the stale permutation (fallbacks). Every replay, hit or fallback,
    // must be bitwise equal to a from-scratch reference-heap run. Engines
    // are pinned to SchedMode::Cached explicitly so the sweep tests the
    // cached path regardless of the process-wide BSF_SCHED value.
    let mut rng = Rng::new(0x0CDE);
    let (mut hits, mut fallbacks) = (0u64, 0u64);
    for case in 0..80u64 {
        let n = 2 + rng.below(160) as usize;
        let n_res = 1 + rng.below(8) as u32;
        let mut resources = Vec::with_capacity(n);
        let mut durations = Vec::with_capacity(n);
        let mut eng = Engine::new();
        eng.set_sched_mode(Some(SchedMode::Cached));
        for _ in 0..n {
            let res = rng.below(n_res as u64) as u32;
            let dur = rng.range(0.0, 3.0);
            resources.push(res);
            durations.push(dur);
            eng.task(res, dur);
        }
        let mut edges: Vec<(TaskId, TaskId)> = Vec::new();
        for j in 1..n {
            let tries = 1 + rng.below(3);
            for _ in 0..tries {
                let i = rng.below(j as u64) as usize;
                eng.dep(i as TaskId, j as TaskId);
                edges.push((i as TaskId, j as TaskId));
            }
        }
        // First run records the cache; it must already match the heap.
        let mut reference = ReferenceScheduler::new(resources.clone(), durations.clone(), &edges);
        let want = reference.run().to_vec();
        for (i, (w, g)) in want.iter().zip(eng.run()).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "case {case}: first run, task {i}");
        }
        for round in 0..4u64 {
            match round {
                // Unchanged durations: replays the recording run exactly.
                0 => {}
                // Gentle multiplicative nudges: order usually survives.
                1 => {
                    for (id, d) in durations.iter_mut().enumerate() {
                        *d *= 1.0 + rng.range(-0.02, 0.02);
                        eng.set_duration(id as TaskId, *d);
                    }
                }
                // Coarse tie-heavy grids: ready order scrambles, ties
                // abound — the stale cache must be rejected, not trusted.
                _ => {
                    for (id, d) in durations.iter_mut().enumerate() {
                        *d = rng.below(3) as f64 * 0.5;
                        eng.set_duration(id as TaskId, *d);
                    }
                }
            }
            let mut reference =
                ReferenceScheduler::new(resources.clone(), durations.clone(), &edges);
            let want = reference.run().to_vec();
            let got = eng.run_reuse();
            for (i, (w, g)) in want.iter().zip(got).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    g.to_bits(),
                    "case {case} round {round}: task {i} (n={n}, res={n_res})"
                );
            }
        }
        let c = eng.sched_counters();
        hits += c.cached_hits;
        fallbacks += c.fallbacks;
    }
    // The sweep must exercise both branches of the dispatch. Hits are
    // guaranteed by the unchanged-duration rounds (forward edges make the
    // recorded order lexicographically valid under identical durations);
    // fallbacks by the grid redraws.
    assert!(hits > 0, "order cache never hit across the sweep");
    assert!(fallbacks > 0, "validity check never rejected a stale cache");
}

/// One random DAG for the lane-batch races: task resources/durations and
/// forward edges, drawn once per case so every width sees the same graph.
fn random_dag(rng: &mut Rng) -> (Vec<u32>, Vec<f64>, Vec<(TaskId, TaskId)>) {
    let n = 2 + rng.below(140) as usize;
    let n_res = 1 + rng.below(8) as u32;
    let mut resources = Vec::with_capacity(n);
    let mut durations = Vec::with_capacity(n);
    for _ in 0..n {
        resources.push(rng.below(n_res as u64) as u32);
        durations.push(rng.range(0.0, 3.0));
    }
    let mut edges = Vec::new();
    for j in 1..n {
        let tries = 1 + rng.below(3);
        for _ in 0..tries {
            let i = rng.below(j as u64) as usize;
            edges.push((i as TaskId, j as TaskId));
        }
    }
    (resources, durations, edges)
}

/// A lane engine (vector pass forced on, pinned width) and its scalar
/// twin, both holding the given graph with order caches recorded.
fn lane_engine_pair(
    resources: &[u32],
    durations: &[f64],
    edges: &[(TaskId, TaskId)],
    width: usize,
) -> (Engine, Engine) {
    let mut eng = Engine::new();
    let mut twin = Engine::new();
    eng.set_sched_mode(Some(SchedMode::Cached));
    eng.set_lane_mode(Some(true));
    eng.set_lane_width(Some(width));
    twin.set_sched_mode(Some(SchedMode::Cached));
    for (&res, &dur) in resources.iter().zip(durations) {
        eng.task(res, dur);
        twin.task(res, dur);
    }
    for &(i, j) in edges {
        eng.dep(i, j);
        twin.dep(i, j);
    }
    let a = eng.run().to_vec();
    let b = twin.run();
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "recording run, task {i}");
    }
    (eng, twin)
}

/// Run `rounds` lane batches of `lanes` duration sets against the twin's
/// scalar loop and assert every real lane bitwise. Round 0 replays the
/// recorded durations unchanged (guaranteed all-lane hit), round 1 nudges
/// gently (usually valid), round 2 redraws on a coarse tie-heavy grid
/// (scrambles some lane's ready order — forced fallback).
fn race_lane_batches(
    eng: &mut Engine,
    twin: &mut Engine,
    durations: &[f64],
    lanes: usize,
    rng: &mut Rng,
    what: &str,
) {
    for round in 0..3u64 {
        let sets: Vec<Vec<f64>> = (0..lanes)
            .map(|_| {
                durations
                    .iter()
                    .map(|d| match round {
                        0 => *d,
                        1 => d * (1.0 + rng.range(-0.02, 0.02)),
                        _ => rng.below(3) as f64 * 0.5,
                    })
                    .collect()
            })
            .collect();
        let mat = eng.lane_durations_mut(lanes);
        for (m, set) in sets.iter().enumerate() {
            for (i, &d) in set.iter().enumerate() {
                mat[i * lanes + m] = d;
            }
        }
        eng.run_lanes(lanes);
        for (m, set) in sets.iter().enumerate() {
            for (i, &d) in set.iter().enumerate() {
                twin.set_duration(i as TaskId, d);
            }
            let want = twin.run_reuse();
            let got = eng.lane_finish();
            for (i, w) in want.iter().enumerate() {
                assert_eq!(
                    w.to_bits(),
                    got[i * lanes + m].to_bits(),
                    "{what} round {round} lane {m}: task {i}"
                );
            }
            assert_eq!(
                twin.last_makespan().to_bits(),
                eng.lane_makespans()[m].to_bits(),
                "{what} round {round} lane {m}: makespan"
            );
        }
    }
}

#[test]
fn prop_lane_batched_replay_matches_scalar_loop_on_random_dags() {
    // Race the lane-batched replay — at BOTH dispatch widths, 4 and 8,
    // pinned per engine via set_lane_width — against a twin engine
    // running the same duration sets through the scalar set_duration +
    // run_reuse loop in lane order. Gentle per-lane perturbations mostly
    // keep every lane's pop order valid (vector hits); coarse tie-heavy
    // per-lane grid redraws scramble some lane's ready order and force
    // the all-lane validity check to abort the batch (per-lane
    // fallbacks, re-run sequentially with cache refreshes). Every lane
    // of every batch must equal the scalar loop bitwise — and the scalar
    // loop itself is pinned against the reference heap by the props
    // above, so this transitively pins the lane pass to the heap too.
    // Both engines are pinned to SchedMode::Cached and the lane engine
    // forces the vector pass on, so the sweep races both paths whatever
    // BSF_SCHED / BSF_LANES / BSF_LANE_WIDTH say (the process-wide
    // BSF_KERNEL still selects the lane implementation family; width 8
    // without avx512f runs the width-generic scalar twin — raced all the
    // same).
    let mut rng = Rng::new(0x1A2E5);
    let (mut lane_hits, mut lane_falls) = (0u64, 0u64);
    for case in 0..40u64 {
        let (resources, durations, edges) = random_dag(&mut rng);
        for width in [4usize, 8] {
            let (mut eng, mut twin) = lane_engine_pair(&resources, &durations, &edges, width);
            race_lane_batches(
                &mut eng,
                &mut twin,
                &durations,
                width,
                &mut rng,
                &format!("case {case} width {width}"),
            );
            let c = eng.sched_counters();
            assert_eq!(c.lane_width, width as u64, "case {case}: dispatched width");
            assert_eq!(c.lane_pad_replays, 0, "case {case} width {width}: full batches");
            lane_hits += c.lane_hits;
            lane_falls += c.lane_fallbacks;
        }
    }
    // The sweep must exercise both branches of the batch dispatch: hits
    // from the gently perturbed rounds, forced per-lane fallbacks from
    // the tie-heavy grid redraws.
    assert!(lane_hits > 0, "lane pass never served a batch across the sweep");
    assert!(lane_falls > 0, "no lane ever failed the validity check across the sweep");
}

#[test]
fn prop_padded_remainder_batches_match_scalar_loop_on_random_dags() {
    // Adversarial remainder-padding race: batches of 1 ≤ lanes < width
    // ride the lane pass padded with duplicates of the last real lane,
    // and the pad results are discarded. Whatever the pad lane does —
    // including carrying the tie-heavy redraws of its source lane that
    // invalidate the cached order — every *real* lane must equal the
    // scalar loop bitwise, the compacted lane buffers must hold exactly
    // the real lanes, and the pad must never perturb counters beyond
    // lane_pad_replays (lane_hits counts real lanes only).
    let mut rng = Rng::new(0x9AD5);
    let (mut lane_hits, mut lane_falls, mut pads) = (0u64, 0u64, 0u64);
    for case in 0..40u64 {
        let (resources, durations, edges) = random_dag(&mut rng);
        for width in [4usize, 8] {
            let lanes = 1 + rng.below(width as u64 - 1) as usize;
            let (mut eng, mut twin) = lane_engine_pair(&resources, &durations, &edges, width);
            race_lane_batches(
                &mut eng,
                &mut twin,
                &durations,
                lanes,
                &mut rng,
                &format!("case {case} width {width} lanes {lanes}"),
            );
            let c = eng.sched_counters();
            assert_eq!(c.lane_width, width as u64, "case {case}: dispatched width");
            // Vector-served batches pad (width - lanes) discarded lanes
            // each; fallback batches run sequentially, padding nothing.
            let vector_batches = c.lane_hits / lanes as u64;
            assert_eq!(
                c.lane_pad_replays,
                vector_batches * (width - lanes) as u64,
                "case {case} width {width} lanes {lanes}: pad economics"
            );
            lane_hits += c.lane_hits;
            lane_falls += c.lane_fallbacks;
            pads += c.lane_pad_replays;
        }
    }
    assert!(lane_hits > 0, "padded pass never served a batch across the sweep");
    assert!(lane_falls > 0, "no padded batch ever fell back across the sweep");
    assert!(pads > 0, "no pad lane ever ran across the sweep");
}

#[test]
fn prop_kernel_dispatch_bitwise_identical() {
    // The AVX2 and scalar kernels perform the same IEEE-754 operation
    // sequence, so they must agree bit for bit on every input — every
    // length class mod 4 (vector remainders) appears in the sweep.
    if !kernels::available(kernels::KernelKind::Avx2) {
        eprintln!("skipping AVX2 half: unsupported on this host (scalar-only arch)");
        return;
    }
    let mut rng = Rng::new(0x51AD);
    for case in 0..CASES {
        let n = rng.below(260) as usize;
        let mk = |rng: &mut Rng| -> Vec<f64> { (0..n).map(|_| rng.normal() * 3.0).collect() };
        let r0 = mk(&mut rng);
        let r1 = mk(&mut rng);
        let r2 = mk(&mut rng);
        let r3 = mk(&mut rng);
        let x = mk(&mut rng);
        let s = kernels::dot_with(kernels::KernelKind::Scalar, &r0, &x);
        let v = kernels::dot_with(kernels::KernelKind::Avx2, &r0, &x);
        assert_eq!(s.to_bits(), v.to_bits(), "case {case}: dot n={n} ({s} vs {v})");
        let a = kernels::dot4_with(kernels::KernelKind::Scalar, &r0, &r1, &r2, &r3, &x);
        let b = kernels::dot4_with(kernels::KernelKind::Avx2, &r0, &r1, &r2, &r3, &x);
        for (i, (sa, sb)) in [(a.0, b.0), (a.1, b.1), (a.2, b.2), (a.3, b.3)]
            .iter()
            .enumerate()
        {
            assert_eq!(sa.to_bits(), sb.to_bits(), "case {case}: dot4 row {i} n={n}");
        }
    }
}

#[test]
fn prop_blocked_matvec_equals_scalar_composition_bitwise() {
    // Whatever kernel `BSF_KERNEL`/auto-detection selected for this
    // process, the blocked column-range matvec must equal the per-row
    // scalar dot composition bitwise — random shapes including remainder
    // rows (rows % 4) and remainder columns (width % 4), partial column
    // ranges, and pre-populated accumulators.
    let mut rng = Rng::new(0xB10C);
    for case in 0..CASES {
        let rows = 1 + rng.below(40) as usize;
        let cols = rng.below(65) as usize;
        let m = Matrix::from_fn(rows, cols, |i, j| {
            (((i * 37 + j * 11 + case) % 29) as f64) * 0.21 - 3.0
        });
        let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let j0 = rng.below(cols as u64 + 1) as usize;
        let j1 = j0 + rng.below((cols - j0) as u64 + 1) as usize;
        let mut y: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let y0 = y.clone();
        m.col_block_matvec_acc(j0, j1, &x[j0..j1], &mut y);
        for i in 0..rows {
            let want = y0[i]
                + kernels::dot_with(kernels::KernelKind::Scalar, &m.row(i)[j0..j1], &x[j0..j1]);
            assert_eq!(
                want.to_bits(),
                y[i].to_bits(),
                "case {case}: row {i} rows={rows} cols={cols} j0={j0} j1={j1} \
                 (active kernel {:?})",
                kernels::active()
            );
        }
    }
}

#[test]
fn prop_collectives_cover_everyone_log_depth() {
    let mut rng = Rng::new(0xC011);
    for _ in 0..CASES {
        let k = 1 + rng.below(1_000) as usize;
        let s = CollectiveSchedule::broadcast(CollectiveAlgo::BinomialTree, k);
        // depth = ceil(log2(k+1))
        let want = (usize::BITS - k.leading_zeros()) as usize
            + usize::from(!(k + 1).is_power_of_two() && k.count_ones() != 0 && false);
        let depth = s.depth();
        let lo = ((k + 1) as f64).log2().ceil() as usize;
        assert_eq!(depth, lo.max(1).min(depth.max(lo)), "k={k} depth={depth} want~{want}");
        // coverage
        let mut has = vec![false; k + 1];
        has[0] = true;
        for round in &s.rounds {
            for &(from, to) in round {
                assert!(has[from], "k={k}: sender without message");
                has[to] = true;
            }
        }
        assert!(has.iter().all(|&h| h), "k={k}: incomplete broadcast");
    }
}

#[test]
fn prop_jitter_preserves_mean_scale() {
    // With mean-one multiplicative jitter, the average simulated iteration
    // should stay within a few percent of the deterministic one.
    let l = 4_096;
    let mut det = AnalyticCost { t_map_full: 0.1, l, t_a: 1e-6, t_p: 1e-5 };
    let base = simulate_iteration(32, l, &SimParams::new(1024, 1024), &mut det, &mut Rng::new(1));
    let mut params = SimParams::new(1024, 1024);
    params.jitter_comp = 0.05;
    params.jitter_comm = 0.05;
    let mut rng = Rng::new(2);
    let n = 300;
    let mean: f64 = (0..n)
        .map(|_| simulate_iteration(32, l, &params, &mut det, &mut rng).total)
        .sum::<f64>()
        / n as f64;
    let rel = (mean - base.total).abs() / base.total;
    // Jitter on the max of parallel workers biases slightly upward — that
    // is real straggler physics — but must stay moderate at sigma=0.05.
    assert!(rel < 0.10, "rel drift {rel}");
}

/// Random payload fields layered over a fixed structural tuple: list
/// size, word counts, network model and jitter sigmas all redrawn per
/// call, structural fields (`algo`, `reduce_mode`, `masters`) pinned.
fn random_payload(
    rng: &mut Rng,
    algo: CollectiveAlgo,
    reduce_mode: ReduceMode,
    masters: usize,
) -> (usize, SimParams) {
    let l = 64 + rng.below(30_000) as usize;
    let mut p = SimParams::new(1 + rng.below(8_192) as usize, 1 + rng.below(512) as usize);
    if rng.below(2) == 0 {
        p.net = NetworkParams::fast_fabric();
    }
    p.jitter_comp = if rng.below(2) == 0 { 0.0 } else { rng.range(0.01, 0.2) };
    p.jitter_comm = if rng.below(2) == 0 { 0.0 } else { rng.range(0.01, 0.2) };
    p.algo = algo;
    p.reduce_mode = reduce_mode;
    p.masters = masters;
    (l, p)
}

#[test]
fn prop_equal_shape_class_builds_identical_structure() {
    // The grouping contract is asymmetric: a missed match only costs a
    // rebuild, a spurious match replays the WRONG graph. So the key must
    // be exactly the set of fields the clean-build graph structure
    // depends on — no more (or grouping never fires across payloads), no
    // less (or two different graphs share a template). Random structural
    // tuples with independently random payloads pin both directions:
    // equal tuple ⇒ equal `ShapeClass` AND bitwise-equal `structure()`
    // (task count, resources, CSR edges, duration-tag column, MapFold
    // fan-out, fold counts); any structural perturbation ⇒ unequal keys,
    // which is precisely the predicate `flat_groups` buckets on.
    let algos = [CollectiveAlgo::BinomialTree, CollectiveAlgo::Linear];
    let modes = [ReduceMode::TreeMasterFold, ReduceMode::InTree, ReduceMode::GatherThenFold];
    let mut rng = Rng::new(0x5AFE);
    let mut split_checks = 0u64;
    for case in 0..60u64 {
        let k = 1 + rng.below(64) as usize;
        let masters = 1 + rng.below(12) as usize;
        let algo = algos[rng.below(2) as usize];
        let mode = modes[rng.below(3) as usize];
        let (la, pa) = random_payload(&mut rng, algo, mode, masters);
        let (lb, pb) = random_payload(&mut rng, algo, mode, masters);
        assert_eq!(
            ShapeClass::of(k, &pa),
            ShapeClass::of(k, &pb),
            "case {case}: payload leaked into the shape key (k={k})"
        );
        let ta = IterationTemplate::new(k, la, &pa);
        let tb = IterationTemplate::new(k, lb, &pb);
        assert_eq!(ta.shape_class(), ShapeClass::of(k, &pa), "case {case}: template key");
        assert_eq!(
            ta.structure(),
            tb.structure(),
            "case {case}: equal shape built different graphs \
             (k={k} m={masters} algo={algo:?} mode={mode:?})"
        );
        // Every structural perturbation must split the key (no grouping).
        let shape = ShapeClass::of(k, &pa);
        assert_ne!(shape, ShapeClass::of(k + 1, &pa), "case {case}: k must split");
        let mut q = pa.clone();
        q.algo = algos[(algos.iter().position(|&a| a == algo).unwrap() + 1) % 2];
        assert_ne!(shape, ShapeClass::of(k, &q), "case {case}: algo must split");
        let mut q = pa.clone();
        q.reduce_mode = modes[(modes.iter().position(|&m| m == mode).unwrap() + 1) % 3];
        assert_ne!(shape, ShapeClass::of(k, &q), "case {case}: reduce mode must split");
        // Masters enters the key saturated at K: a change is structural
        // exactly when it moves `masters.min(k)`.
        if masters < k {
            let mut q = pa.clone();
            q.masters = k + 3;
            assert_ne!(shape, ShapeClass::of(k, &q), "case {case}: masters must split");
            split_checks += 1;
        }
    }
    assert!(split_checks > 0, "masters split direction never exercised");
}
