//! Integration: the AOT artifacts through the PJRT runtime — the L1→L2→
//! runtime path that the Python test suite cannot cover (it validates the
//! kernels pre-lowering; this validates the compiled HLO the Rust workers
//! actually execute).
//!
//! These tests are skipped (with a note) when `artifacts/` is absent; the
//! Makefile orders `make artifacts` before `cargo test`.

use bsf::linalg::generators::paper_system;
use bsf::runtime::{KernelRuntime, Tensor};
use bsf::util::Rng;

fn runtime() -> Option<KernelRuntime> {
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(KernelRuntime::open(dir).expect("open runtime"))
}

#[test]
fn jacobi_map_artifact_matches_native_matvec() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    for n in [256usize, 512] {
        let name = rt.manifest().jacobi_map(n).expect("artifact");
        let b = rt.block();
        let c: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
        let x: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
        let out = rt
            .execute(&name, &[Tensor::mat(c.clone(), n, b), Tensor::vec(x.clone())])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), n);
        for i in 0..n {
            let want: f64 = (0..b).map(|j| c[i * b + j] * x[j]).sum();
            assert!((out[0][i] - want).abs() < 1e-9 * want.abs().max(1.0), "n={n} row {i}");
        }
    }
}

#[test]
fn jacobi_post_artifact_matches_formula() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let mut rng = Rng::new(2);
    let s: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let d: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let out = rt
        .execute(
            "jacobi_post_n256",
            &[Tensor::vec(s.clone()), Tensor::vec(d.clone()), Tensor::vec(x.clone())],
        )
        .unwrap();
    // outputs: (x_new, sqnorm)
    assert_eq!(out.len(), 2);
    let mut sq = 0.0;
    for i in 0..n {
        let xn = s[i] + d[i];
        assert!((out[0][i] - xn).abs() < 1e-12);
        sq += (xn - x[i]) * (xn - x[i]);
    }
    assert!((out[1][0] - sq).abs() < 1e-9 * sq);
}

#[test]
fn jacobi_step_artifact_matches_full_iteration() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let sys = paper_system(n);
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
    let out = rt
        .execute(
            "jacobi_step_n256",
            &[
                Tensor::mat(sys.c.as_slice().to_vec(), n, n),
                Tensor::vec(sys.d.clone()),
                Tensor::vec(x.clone()),
            ],
        )
        .unwrap();
    let want_s = sys.c.matvec(&x);
    for i in 0..n {
        let want = want_s[i] + sys.d[i];
        assert!((out[0][i] - want).abs() < 1e-9, "row {i}");
    }
}

#[test]
fn gravity_artifacts_match_native() {
    let Some(rt) = runtime() else { return };
    let b = rt.block();
    let name = rt.manifest().gravity_map().expect("artifact");
    let mut rng = Rng::new(3);
    let y: Vec<f64> = (0..b * 3).map(|_| rng.normal() * 5.0).collect();
    let m: Vec<f64> = (0..b).map(|_| rng.uniform() + 0.5).collect();
    let x = vec![20.0, 0.0, 0.0];
    let out = rt
        .execute(&name, &[Tensor::mat(y.clone(), b, 3), Tensor::vec(m.clone()), Tensor::vec(x.clone())])
        .unwrap();
    let mut want = [0.0f64; 3];
    for i in 0..b {
        let d = [y[i * 3] - x[0], y[i * 3 + 1] - x[1], y[i * 3 + 2] - x[2]];
        let r2 = (d[0] * d[0] + d[1] * d[1] + d[2] * d[2]).max(1e-30);
        let w = m[i] / r2;
        want[0] += w * d[0];
        want[1] += w * d[1];
        want[2] += w * d[2];
    }
    for c in 0..3 {
        assert!((out[0][c] - want[c]).abs() < 1e-9 * want[c].abs().max(1.0));
    }

    // gravity_post: Δt rule.
    let out = rt
        .execute(
            "gravity_post",
            &[
                Tensor::vec(vec![1.0, 2.0, 2.0]), // ‖V‖² = 9
                Tensor::vec(vec![0.0, 1.0, 0.0]), // ‖α‖⁴ = 1
                Tensor::vec(vec![0.0, 0.0, 0.0]),
                Tensor::scalar(4.5),
            ],
        )
        .unwrap();
    // (v_new, x_new, delta_t); delta_t = 4.5/9 = 0.5
    assert!((out[2][0] - 0.5).abs() < 1e-12);
    assert!((out[0][1] - 2.5).abs() < 1e-12); // v_y + 1*0.5
}

#[test]
fn cimmino_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let n = 256;
    let b = rt.block();
    let name = rt.manifest().cimmino_map(n).expect("artifact");
    let mut rng = Rng::new(4);
    let a: Vec<f64> = (0..b * n).map(|_| rng.normal()).collect();
    let rhs: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
    let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let out = rt
        .execute(&name, &[Tensor::mat(a.clone(), b, n), Tensor::vec(rhs.clone()), Tensor::vec(x.clone())])
        .unwrap();
    let mut want = vec![0.0; n];
    for i in 0..b {
        let row = &a[i * n..(i + 1) * n];
        let resid: f64 = row.iter().zip(&x).map(|(r, xi)| r * xi).sum::<f64>() - rhs[i];
        if resid > 0.0 {
            let nrm2: f64 = row.iter().map(|r| r * r).sum();
            let w = resid / nrm2;
            for (acc, r) in want.iter_mut().zip(row) {
                *acc -= w * r;
            }
        }
    }
    for i in 0..n {
        assert!((out[0][i] - want[i]).abs() < 1e-9 * want[i].abs().max(1.0), "col {i}");
    }
}

#[test]
fn execute_rejects_wrong_shapes() {
    let Some(rt) = runtime() else { return };
    let err = rt
        .execute("jacobi_map_n256", &[Tensor::vec(vec![0.0; 10]), Tensor::vec(vec![0.0; 10])])
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape"));
    assert!(rt.execute("no_such_artifact", &[]).is_err());
}

#[test]
fn executable_cache_reuses_compilations() {
    let Some(rt) = runtime() else { return };
    assert_eq!(rt.compiled_count(), 0);
    rt.warm("jacobi_post_n256").unwrap();
    rt.warm("jacobi_post_n256").unwrap();
    assert_eq!(rt.compiled_count(), 1);
}
