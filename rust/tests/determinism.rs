//! Determinism suite for the zero-allocation sweep pipeline.
//!
//! Two contracts the perf work must never break:
//!
//! 1. **Parallel == serial, bitwise.** A K-sweep evaluated across N
//!    threads must produce bit-identical `f64`s to the single-threaded
//!    sweep, because every K draws from its own provider instance and RNG
//!    stream (`Rng::split`, keyed by K) rather than sharing a serially
//!    threaded generator.
//! 2. **Replication == naive loop, bitwise.** With zero jitter and a
//!    deterministic provider, `simulate_run` simulates one iteration and
//!    replicates it; that must equal running the full `iters` loop.
//! 3. **Order-cached == calendar, bitwise.** The engine's order-cached
//!    linear replay must produce the calendar queue's exact schedule on
//!    every input (hit or fallback). The explicit two-engine race below
//!    pins it in-process; CI additionally runs this whole suite under
//!    both `BSF_SCHED=calendar` and `BSF_SCHED=cached`, so every
//!    pooled-vs-serial equality above doubles as a cross-scheduler check.
//! 4. **Lane-batched == one-at-a-time, bitwise.** `run_into`'s jittered
//!    branch groups replays into batches of the dispatched lane width
//!    (8 with AVX-512, else 4; `BSF_LANE_WIDTH` overrides), and the final
//!    partial batch rides the same lane pass padded with a discarded
//!    duplicate lane — no scalar remainder. Sweep cells sharing a
//!    `ShapeClass` (equal graph structure; sizes, cost params and jitter
//!    free to differ) additionally ride shared batches through one
//!    template (`run_group_into`, payload swaps via `bind_cell`). All of
//!    it must equal calling `replay()` once per iteration per cell. CI
//!    also runs this suite under `BSF_LANES=off` (every batch through
//!    the sequential fallback), under `BSF_GROUP=off` (every cell its
//!    own group), and, on AVX-512 runners, under `BSF_LANE_WIDTH=8` —
//!    results must not move.

use bsf::experiments::{
    analytic_provider, boundary_row, boundary_rows, paper_gravity_params, paper_jacobi_params,
    simulated_curve_threads, simulated_curves, BoundarySpec, ExperimentCtx, SweepJob,
};
use bsf::simulator::{
    simulate_iteration, simulate_iteration_full, simulate_run, AnalyticCost, CostFactory,
    GroupCell, IterationTemplate, IterationTiming, SchedMode, SimParams, TaskId,
};
use bsf::util::Rng;

fn assert_bitwise_eq(a: &IterationTiming, b: &IterationTiming, what: &str) {
    for (x, y, field) in [
        (a.broadcast_done, b.broadcast_done, "broadcast_done"),
        (a.map_done, b.map_done, "map_done"),
        (a.reduce_done, b.reduce_done, "reduce_done"),
        (a.post_done, b.post_done, "post_done"),
        (a.total, b.total, "total"),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: {field} differs ({x} vs {y})");
    }
}

#[test]
fn parallel_sweep_bitwise_equals_serial() {
    let ctx = ExperimentCtx::default();
    let params = paper_jacobi_params(5_000).unwrap();
    let prov = analytic_provider(&params);
    let sim = SimParams::new(5_000, 5_000);
    let ks: Vec<usize> = (1..=48).collect();
    let reference =
        simulated_curve_threads(&ctx, &sim, 5_000, &prov, &ks, 3, &mut Rng::new(42), 1);
    for threads in [1usize, 4, 8] {
        let got =
            simulated_curve_threads(&ctx, &sim, 5_000, &prov, &ks, 3, &mut Rng::new(42), threads);
        assert_eq!(reference.len(), got.len());
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!(a.k, b.k, "threads={threads}");
            assert_eq!(
                a.t_k.to_bits(),
                b.t_k.to_bits(),
                "threads={threads} K={}: t_k {} vs {}",
                a.k,
                a.t_k,
                b.t_k
            );
            assert_eq!(
                a.speedup.to_bits(),
                b.speedup.to_bits(),
                "threads={threads} K={}: speedup",
                a.k
            );
        }
    }
}

#[test]
fn parallel_sweep_bitwise_equals_serial_with_jitter() {
    // Jitter makes every K consume rng draws; per-K split streams keep the
    // draws independent of evaluation order, so the bitwise guarantee must
    // survive stochastic configurations too.
    let ctx = ExperimentCtx::default();
    let params = paper_jacobi_params(1_500).unwrap();
    let prov = analytic_provider(&params);
    let mut sim = SimParams::new(1_500, 1_500);
    sim.jitter_comp = 0.15;
    sim.jitter_comm = 0.10;
    let ks: Vec<usize> = (1..=32).collect();
    let reference =
        simulated_curve_threads(&ctx, &sim, 1_500, &prov, &ks, 4, &mut Rng::new(7), 1);
    for threads in [4usize, 8] {
        let got =
            simulated_curve_threads(&ctx, &sim, 1_500, &prov, &ks, 4, &mut Rng::new(7), threads);
        for (a, b) in reference.iter().zip(&got) {
            assert_eq!(a.t_k.to_bits(), b.t_k.to_bits(), "threads={threads} K={}", a.k);
        }
    }
}

#[test]
fn sweep_stream_is_keyed_by_k_not_grid() {
    // The per-K stream depends on K itself, so refining the sweep grid
    // must not change the value simulated at a K that appears in both.
    let ctx = ExperimentCtx::default();
    let params = paper_jacobi_params(1_500).unwrap();
    let prov = analytic_provider(&params);
    let mut sim = SimParams::new(1_500, 1_500);
    sim.jitter_comp = 0.1;
    let coarse: Vec<usize> = vec![1, 8, 16, 32];
    let fine: Vec<usize> = (1..=32).collect();
    let a = simulated_curve_threads(&ctx, &sim, 1_500, &prov, &coarse, 3, &mut Rng::new(5), 2);
    let b = simulated_curve_threads(&ctx, &sim, 1_500, &prov, &fine, 3, &mut Rng::new(5), 2);
    for pa in &a {
        let pb = b.iter().find(|p| p.k == pa.k).expect("shared K");
        assert_eq!(pa.t_k.to_bits(), pb.t_k.to_bits(), "K={}", pa.k);
    }
}

#[test]
fn pooled_multi_sweep_bitwise_equals_sequential_sweeps() {
    // The (experiment × size × K) work queue must reproduce the serial
    // size-by-size pipeline bit for bit, at any thread count, jittered
    // included: jobs pre-fork their RNG roots in construction order, so
    // execution order (and worker engine reuse) cannot leak into results.
    let ctx = ExperimentCtx::default();
    let p1 = paper_jacobi_params(1_500).unwrap();
    let p2 = paper_jacobi_params(5_000).unwrap();
    let prov1 = analytic_provider(&p1);
    let prov2 = analytic_provider(&p2);
    let mut sim1 = SimParams::new(1_500, 1_500);
    sim1.jitter_comp = 0.12;
    let mut sim2 = SimParams::new(5_000, 5_000);
    sim2.jitter_comm = 0.08;
    let ks: Vec<usize> = (1..=24).collect();

    // Serial reference: two sweeps in sequence off one rng.
    let mut rng = Rng::new(2027);
    let a1 = simulated_curve_threads(&ctx, &sim1, 1_500, &prov1, &ks, 3, &mut rng, 1);
    let a2 = simulated_curve_threads(&ctx, &sim2, 5_000, &prov2, &ks, 3, &mut rng, 1);

    for threads in [1usize, 4, 8] {
        let mut rng = Rng::new(2027);
        let jobs = vec![
            SweepJob::new(sim1.clone(), 1_500, &prov1, ks.clone(), 3, &mut rng),
            SweepJob::new(sim2.clone(), 5_000, &prov2, ks.clone(), 3, &mut rng),
        ];
        let got = simulated_curves(&jobs, threads);
        assert_eq!(got.len(), 2);
        for (want, have) in [(&a1, &got[0]), (&a2, &got[1])] {
            assert_eq!(want.len(), have.len());
            for (a, b) in want.iter().zip(have.iter()) {
                assert_eq!(a.k, b.k, "threads={threads}");
                assert_eq!(
                    a.t_k.to_bits(),
                    b.t_k.to_bits(),
                    "threads={threads} K={}: t_k {} vs {}",
                    a.k,
                    a.t_k,
                    b.t_k
                );
                assert_eq!(a.speedup.to_bits(), b.speedup.to_bits(), "threads={threads} K={}", a.k);
            }
        }
    }
}

#[test]
fn pooled_boundary_rows_bitwise_equal_serial_rows() {
    // The batched boundary comparison (the queue explorer/sqrt_law feed
    // their cells/sizes through) must reproduce the one-spec-at-a-time
    // pipeline bit for bit — including across *different applications* in
    // one pool, since the RNG roots fork in spec order at job
    // construction, not at execution.
    let ctx = ExperimentCtx { quick: true, ..Default::default() };
    let p1 = paper_jacobi_params(1_500).unwrap();
    let p2 = paper_gravity_params(300).unwrap();
    let prov1 = analytic_provider(&p1);
    let prov2 = analytic_provider(&p2);
    let specs = vec![
        BoundarySpec { n: 1_500, params: p1, words_down: 1_500, words_up: 1_500, factory: &prov1 },
        BoundarySpec { n: 300, params: p2, words_down: 3, words_up: 3, factory: &prov2 },
    ];
    let pooled = boundary_rows(&ctx, &specs, &mut Rng::new(0xE0));
    let mut rng = Rng::new(0xE0);
    let serial: Vec<_> = specs
        .iter()
        .map(|s| boundary_row(&ctx, s.n, &s.params, s.words_down, s.words_up, s.factory, &mut rng))
        .collect();
    assert_eq!(pooled.len(), serial.len());
    for (a, b) in pooled.iter().zip(&serial) {
        assert_eq!(a.n, b.n);
        assert_eq!(a.k_bsf.to_bits(), b.k_bsf.to_bits(), "n={}", a.n);
        assert_eq!(a.k_test.to_bits(), b.k_test.to_bits(), "n={}", a.n);
        assert_eq!(a.peak_speedup.to_bits(), b.peak_speedup.to_bits(), "n={}", a.n);
        assert_eq!(a.plateau, b.plateau, "n={}", a.n);
    }
}

#[test]
fn deterministic_replication_matches_naive_loop() {
    let l = 2_048;
    let params = SimParams::new(l, l);
    let mut prov = AnalyticCost { t_map_full: 0.3, l, t_a: 1e-6, t_p: 1e-5 };
    for k in [1usize, 7, 16, 64] {
        let fast = simulate_run(k, l, 9, &params, &mut prov, &mut Rng::new(1));
        assert_eq!(fast.len(), 9);
        // Naive loop: one fresh graph build + run per iteration.
        let naive: Vec<IterationTiming> = (0..9)
            .map(|_| simulate_iteration(k, l, &params, &mut prov, &mut Rng::new(1)))
            .collect();
        for (i, (a, b)) in fast.iter().zip(&naive).enumerate() {
            assert_bitwise_eq(a, b, &format!("K={k} iter={i}"));
        }
    }
}

#[test]
fn jittered_run_matches_per_iteration_rebuild() {
    // The replay path (graph built once) must be bitwise equal to
    // rebuilding the graph every iteration with the same rng stream.
    let l = 1_024;
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.1;
    params.jitter_comm = 0.05;
    let mut prov = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let mut r1 = Rng::new(33);
    let mut r2 = Rng::new(33);
    let reused = simulate_run(12, l, 6, &params, &mut prov, &mut r1);
    let rebuilt: Vec<IterationTiming> =
        (0..6).map(|_| simulate_iteration(12, l, &params, &mut prov, &mut r2)).collect();
    for (i, (a, b)) in reused.iter().zip(&rebuilt).enumerate() {
        assert_bitwise_eq(a, b, &format!("iter={i}"));
    }
}

#[test]
fn order_cached_and_calendar_engines_agree_on_jittered_replays() {
    // Two engines holding the identical Algorithm-2 iteration graph
    // (K=48), one pinned to the pure calendar scheduler and one to the
    // order-cached replay path; the same jittered duration stream drives
    // both. Every replay — cache hit or validity-check fallback alike —
    // must produce the calendar's schedule bit for bit.
    let l = 2_048;
    let params = SimParams::new(l, l);
    let mut prov = AnalyticCost { t_map_full: 0.3, l, t_a: 1e-6, t_p: 1e-5 };
    let (_, mut cal, _) = simulate_iteration_full(48, l, &params, &mut prov, &mut Rng::new(1));
    let (_, mut oc, _) = simulate_iteration_full(48, l, &params, &mut prov, &mut Rng::new(1));
    cal.set_sched_mode(Some(SchedMode::Calendar));
    oc.set_sched_mode(Some(SchedMode::Cached));
    // Prime the order cache under the pinned mode (the template's own
    // first run used the process-wide BSF_SCHED, which may be calendar).
    oc.run_reuse();
    let base = cal.durations().to_vec();
    let mut r_cal = Rng::new(55);
    let mut r_oc = Rng::new(55);
    for (round, sigma) in [0.0, 1e-6, 0.01, 0.1, 0.1, 0.01].into_iter().enumerate() {
        for (id, &b) in base.iter().enumerate() {
            cal.set_duration(id as TaskId, b * r_cal.jitter(sigma));
            oc.set_duration(id as TaskId, b * r_oc.jitter(sigma));
        }
        let want = cal.run_reuse().to_vec();
        let got = oc.run_reuse();
        assert_eq!(want.len(), got.len());
        for (i, (w, g)) in want.iter().zip(got).enumerate() {
            assert_eq!(
                w.to_bits(),
                g.to_bits(),
                "round {round} (sigma={sigma}): task {i} finish {w} vs {g}"
            );
        }
    }
    let c = oc.sched_counters();
    assert!(c.cached_hits >= 1, "the unjittered replay must hit the order cache");
}

#[test]
fn lane_batched_run_into_matches_one_at_a_time_replays() {
    // run_into groups jittered replays into batches of the dispatched
    // lane width (independent duration sets per pass through the engine's
    // order cache), the final partial batch padded with a discarded
    // duplicate lane; on a real Algorithm-2 template the batched path
    // must be bitwise identical to calling replay() once per iteration —
    // draws, hits, per-lane fallbacks, and pad lanes included. 11
    // iterations = two full batches + a padded remainder of three at
    // width 4, or one full batch + a padded remainder of three at 8.
    let l = 1_024;
    let mut params = SimParams::new(l, l);
    params.jitter_comp = 0.1;
    params.jitter_comm = 0.05;
    let mut prov_a = AnalyticCost { t_map_full: 0.2, l, t_a: 1e-6, t_p: 1e-5 };
    let mut prov_b = prov_a.clone();
    let mut batched = IterationTemplate::new(24, l, &params);
    let mut one_at_a_time = IterationTemplate::new(24, l, &params);
    let mut out = Vec::new();
    batched.run_into(11, &mut prov_a, &mut Rng::new(77), &mut out);
    assert_eq!(out.len(), 11);
    let mut rng = Rng::new(77);
    let seq: Vec<IterationTiming> =
        (0..11).map(|_| one_at_a_time.replay(&mut prov_b, &mut rng)).collect();
    for (i, (a, b)) in out.iter().zip(&seq).enumerate() {
        assert_bitwise_eq(a, b, &format!("iter={i}"));
    }
}

#[test]
fn k_adjacent_groups_bitwise_equal_per_cell_loop() {
    // Repeated-K cells (a refinement pass revisiting the same grid) share
    // a shape class, so the pooled queue buckets them onto one worker
    // where their jittered replays ride shared lane passes spanning cell
    // boundaries (run_group_into). The grouped queue must equal the
    // per-cell loop — fresh template + run_into per cell, streams keyed
    // by K exactly as SweepJob keys them — bitwise, at any thread count.
    let p = paper_jacobi_params(1_500).unwrap();
    let prov = analytic_provider(&p);
    let mut sim = SimParams::new(1_500, 1_500);
    sim.jitter_comp = 0.12;
    sim.jitter_comm = 0.06;
    let ks: Vec<usize> = vec![12, 12, 12, 12, 12, 16, 16, 20];
    let iters = 5usize;

    let mut rng = Rng::new(99);
    let job = SweepJob::new(sim.clone(), 1_500, &prov, ks.clone(), iters, &mut rng);
    let reference: Vec<f64> = job
        .ks
        .iter()
        .map(|&k| {
            let mut tmpl = IterationTemplate::new(k, 1_500, &sim);
            let mut provider = prov.instance(k as u64);
            let mut rk = job.root.split(k as u64);
            let mut runs = Vec::new();
            tmpl.run_into(iters, provider.as_mut(), &mut rk, &mut runs);
            runs.iter().map(|t| t.total).sum::<f64>() / runs.len() as f64
        })
        .collect();

    for threads in [1usize, 4, 8] {
        let mut rng = Rng::new(99);
        let jobs = vec![SweepJob::new(sim.clone(), 1_500, &prov, ks.clone(), iters, &mut rng)];
        let got = simulated_curves(&jobs, threads);
        assert_eq!(got[0].len(), reference.len());
        for (i, (point, want)) in got[0].iter().zip(&reference).enumerate() {
            assert_eq!(point.k, ks[i], "threads={threads}");
            assert_eq!(
                point.t_k.to_bits(),
                want.to_bits(),
                "threads={threads} cell={i} K={}: t_k {} vs {}",
                point.k,
                point.t_k,
                want
            );
        }
    }
}

#[test]
fn multi_size_grouped_race_bitwise_equal_per_cell_loop() {
    // The shape-bucketed partition turns a Fig.-6-style grid — four
    // sizes sweeping the *same* K values, with per-size payload words and
    // a couple of repeated Ks — into multi-cell groups that span size
    // boundaries. The grouped queue (BSF_GROUP on, forced per job) must
    // be bitwise equal to the per-cell serial loop (grouping forced off,
    // one thread) at 1/4/8 threads.
    let sizes = [1_500usize, 5_000, 10_000, 16_000];
    let ks: Vec<usize> = vec![6, 10, 14, 18, 22, 10, 14];
    let iters = 4usize;
    let provs: Vec<AnalyticCost> =
        sizes.iter().map(|&n| analytic_provider(&paper_jacobi_params(n).unwrap())).collect();
    let sims: Vec<SimParams> = sizes
        .iter()
        .map(|&n| {
            let mut s = SimParams::new(n, n);
            s.jitter_comp = 0.10;
            s.jitter_comm = 0.05;
            s
        })
        .collect();
    let build_jobs = |group: Option<bool>| {
        let mut rng = Rng::new(0xF166);
        sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                SweepJob::new(sims[i].clone(), n, &provs[i], ks.clone(), iters, &mut rng)
                    .set_group_mode(group)
            })
            .collect::<Vec<_>>()
    };
    let reference = simulated_curves(&build_jobs(Some(false)), 1);
    for threads in [1usize, 4, 8] {
        let got = simulated_curves(&build_jobs(Some(true)), threads);
        assert_eq!(got.len(), reference.len());
        for (s, (want, have)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(want.len(), have.len());
            for (a, b) in want.iter().zip(have) {
                assert_eq!(a.k, b.k, "threads={threads} size={}", sizes[s]);
                assert_eq!(
                    a.t_k.to_bits(),
                    b.t_k.to_bits(),
                    "threads={threads} size={} K={}: t_k {} vs {}",
                    sizes[s],
                    a.k,
                    a.t_k,
                    b.t_k
                );
                assert_eq!(
                    a.speedup.to_bits(),
                    b.speedup.to_bits(),
                    "threads={threads} size={} K={}",
                    sizes[s],
                    a.k
                );
            }
        }
    }

    // Grouped scheduler telemetry is reproducible, SchedCounters
    // included: two identical multi-size grouped runs through one shared
    // template must agree on every counter (group batches, spanned
    // cells, payload rebinds) and on every timing bit.
    let grouped_run = || {
        let mut tmpl = IterationTemplate::new(12, sizes[0], &sims[0]);
        let root = Rng::new(0xC0FFEE);
        let mut cells: Vec<GroupCell> = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| {
                GroupCell::new(provs[i].instance(12), root.split(i as u64), n, &sims[i])
            })
            .collect();
        let mut out = Vec::new();
        tmpl.run_group_into(&mut cells, iters, &mut out);
        (out, tmpl.sched_counters())
    };
    let (o1, c1) = grouped_run();
    let (o2, c2) = grouped_run();
    assert_eq!(c1, c2, "grouped SchedCounters must be reproducible");
    assert!(c1.group_batches > 0, "{c1:?}");
    assert!(c1.group_spanned_cells > 0, "size cells must share batches: {c1:?}");
    assert!(c1.shape_rebinds >= sizes.len() as u64 - 1, "{c1:?}");
    assert_eq!(o1.len(), o2.len());
    for (i, (a, b)) in o1.iter().zip(&o2).enumerate() {
        assert_bitwise_eq(a, b, &format!("repeat grouped run, replay {i}"));
    }
}

#[test]
fn template_task_count_is_iteration_invariant() {
    let l = 4_096;
    let params = SimParams::new(l, l);
    let mut prov = AnalyticCost { t_map_full: 0.5, l, t_a: 1e-6, t_p: 1e-5 };
    let mut rng = Rng::new(3);
    let mut tmpl = IterationTemplate::new(32, l, &params);
    let before = tmpl.task_count();
    for _ in 0..5 {
        tmpl.replay(&mut prov, &mut rng);
    }
    assert_eq!(tmpl.task_count(), before, "replay must not grow the graph");
}
